"""End-to-end system tests: the paper's headline claims as assertions.

These run the FULL Fig.-7 reconstruction through compile + cycle-accurate
execution and gate on Table I within tolerance (DESIGN.md §9 documents the
reconstruction error budget).
"""
import jax
import numpy as np
import pytest

from repro.core import compiler, energy
from repro.core.executor import Executor
from repro.models import kws


@pytest.fixture(scope="module")
def full_kws():
    spec = kws.build_kws_spec()
    params = kws.init_kws_params(jax.random.PRNGKey(0), spec)
    weights, thresholds = kws.export_kws(params, spec)
    prog = compiler.compile_model(
        spec, weights, thresholds,
        rotate_hints=kws.ROTATE_HINTS, rowsplit_hints=kws.ROWSPLIT_HINTS,
    )
    x = np.random.default_rng(0).integers(0, 256, (16000, 1)).astype(np.uint8)
    rep = Executor(prog).run(x)
    return spec, params, prog, x, rep


def test_model_size_matches_paper(full_kws):
    spec = full_kws[0]
    assert spec.total_weights == 646_336
    assert abs(spec.model_size_kb - 652) / 652 < 0.035   # -3.2%
    assert abs(spec.total_macs - 350e6) / 350e6 < 0.01   # +0.2%


def test_macro_constraints(full_kws):
    _, _, prog, _, _ = full_kws
    # every layer fits the wordline/bitline-pair budget
    for b in prog.bindings:
        rows = getattr(b.spec, "rows", 0)
        if rows:
            assert max(c.rows for c in b.chunks) <= 1024
        assert all(c.pairs <= 128 for c in b.chunks)
    # weight SRAM exactly at capacity (the paper's overflow scenario)
    assert prog.wsram.used_bits == 512 * 1024
    assert prog.cim.used_cells <= 1024 * 1024


def test_latency_and_throughput_match_table1(full_kws):
    _, _, _, _, rep = full_kws
    led = rep.ledger
    lat_us = led.latency_s * 1e6
    assert abs(lat_us - 2320) / 2320 < 0.05, lat_us       # +4.2%
    assert abs(led.gops - 150.8) / 150.8 < 0.05, led.gops  # -3.8%


def test_energy_efficiency_calibrated(full_kws):
    _, _, prog, x, rep = full_kws
    target = rep.ledger.macs / 885.86e12
    p = energy.calibrate_e_mac(rep.ledger, target)
    led = Executor(prog, params=p).run(x).ledger
    assert abs(led.tops_per_w - 885.86) / 885.86 < 0.01
    assert abs(led.energy_j * 1e6 - 0.399) / 0.399 < 0.02  # -0.8%
    # default params ship pre-calibrated
    assert abs(rep.ledger.tops_per_w - 885.86) / 885.86 < 0.02


def test_full_model_bitexact_vs_qat(full_kws):
    spec, params, _, x, rep = full_kws
    import jax.numpy as jnp
    qat = np.asarray(kws.kws_forward(params, jnp.array(x[:, 0]), spec))
    np.testing.assert_array_equal(rep.output.ravel().astype(np.float64), qat)


def test_pwb_reduction_within_paper_band(full_kws):
    _, _, prog, x, rep = full_kws
    indep = Executor(prog, fuse_pool=False).run(x)
    red = 100.0 * (1 - rep.ledger.cycles / indep.ledger.cycles)
    # paper: 35.9%; our reconstruction: ~40% (64-bit pool port, DESIGN.md §9)
    assert 25.0 < red < 56.0, red
    np.testing.assert_array_equal(rep.output, indep.output)


def test_instruction_stream_is_decodable(full_kws):
    _, _, prog, _, _ = full_kws
    from repro.core import isa
    decoded = isa.decode_program(prog.words)
    assert isinstance(decoded[-1], isa.HaltInstr)
    assert len(decoded) == len(prog.words)
