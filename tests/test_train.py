"""Training substrate: optimizers, accumulation, checkpointing, restart,
compression (error feedback), attention flash path."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data import lm_data
from repro.models import api, attention
from repro.train import checkpoint as ckpt
from repro.train import compression, loop as tl
from repro.train import optimizer as opt_lib

CFG = get_arch("qwen3-0.6b", smoke=True)


def _trainer(tmp=None, **kw):
    tcfg = tl.TrainConfig(
        opt=opt_lib.OptConfig(name=kw.pop("optimizer", "adamw"), lr=1e-2),
        remat="none", ckpt_dir=tmp, ckpt_every=kw.pop("ckpt_every", 5), **kw
    )
    dcfg = lm_data.DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8,
                              microbatches=tcfg.microbatches)
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    return tl.Trainer(CFG, tcfg, api.loss_fn(CFG, remat="none"), params,
                      lm_data.iterator(dcfg)), dcfg


@pytest.mark.parametrize("optimizer", ["adamw", "sgd", "lion", "adafactor"])
def test_optimizers_reduce_loss(optimizer):
    tr, _ = _trainer(optimizer=optimizer)
    h = tr.run(16)
    # sgd+momentum oscillates early at this lr; compare best-so-far
    best_late = min(m["loss"] for m in h[4:])
    assert best_late < h[0]["loss"], (optimizer, [m["loss"] for m in h])
    assert np.isfinite(h[-1]["loss"])


def test_grad_accumulation_matches_full_batch():
    params = api.init_params(CFG, jax.random.PRNGKey(0))
    loss_fn = api.loss_fn(CFG, remat="none")
    dcfg1 = lm_data.DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8)
    batch = lm_data.batch_at(dcfg1, 0)
    tcfg1 = tl.TrainConfig(opt=opt_lib.OptConfig(lr=1e-2), microbatches=1,
                           remat="none")
    tcfg2 = tl.TrainConfig(opt=opt_lib.OptConfig(lr=1e-2), microbatches=2,
                           remat="none")
    s1 = tl.init_train_state(tcfg1, params)
    s2 = tl.init_train_state(tcfg2, params)
    batch2 = {k: v.reshape(2, 4, *v.shape[1:]) for k, v in batch.items()}
    _, m1 = tl.make_train_step(CFG, tcfg1, loss_fn)(s1, batch)
    _, m2 = tl.make_train_step(CFG, tcfg2, loss_fn)(s2, batch2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)


def test_checkpoint_roundtrip_exact():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)],
    }
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree, meta={"x": 1})
        step, restored, meta = ckpt.restore(d, tree)
    assert step == 7 and meta == {"x": 1}
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, {"x": jnp.ones(3)}, keep_last=2)
        assert ckpt.latest_step(d) == 5
        import pathlib
        assert len(list(pathlib.Path(d).glob("step-*"))) == 2


def test_restart_resumes_training():
    with tempfile.TemporaryDirectory() as d:
        tr, dcfg = _trainer(tmp=d, ckpt_every=4)
        tr.run(8)
        tr2, _ = _trainer(tmp=d, ckpt_every=4)
        assert tr2.step_idx == 8


def test_corrupt_checkpoint_detected():
    import pathlib
    with tempfile.TemporaryDirectory() as d:
        p = ckpt.save(d, 1, {"x": jnp.arange(100.0)})
        blob = bytearray(p.read_bytes())
        blob[len(blob) // 2] ^= 0xFF
        p.write_bytes(bytes(blob))
        with pytest.raises(Exception):
            ckpt.restore(d, {"x": jnp.zeros(100)})


def test_compression_error_feedback_invariant():
    """compressed + residual == corrected gradient (nothing is lost)."""
    t = compression.make_transform("sign1bit")
    g = {"w": jnp.array([0.5, -2.0, 0.1])}
    state: dict = {}
    cg, state = t(g, state)
    np.testing.assert_allclose(
        np.asarray(cg["w"] + state["ef"]["w"]), np.asarray(g["w"]), rtol=1e-6
    )
    # second step folds the residual back in
    cg2, state2 = t(g, state)
    corrected = g["w"] + state["ef"]["w"]
    np.testing.assert_allclose(
        np.asarray(cg2["w"] + state2["ef"]["w"]), np.asarray(corrected),
        rtol=1e-6,
    )


def test_topk_compression_sparsity():
    t = compression.make_transform("topk", topk_frac=0.25)
    g = {"w": jnp.arange(1.0, 17.0)}
    cg, _ = t(g, {})
    assert int(jnp.sum(cg["w"] != 0)) == 4
    assert compression.compressed_bytes(g, "sign1bit") < 16 * 4


def test_straggler_monitor():
    mon = tl.StragglerMonitor(n_hosts=4, factor=2.0)
    times = np.array([1.0, 1.0, 1.0, 1.0])
    for _ in range(3):
        assert mon.record(times) == []
    slow = np.array([1.0, 1.0, 1.0, 8.0])
    flagged = None
    for _ in range(10):
        flagged = mon.record(slow)
    assert flagged == [3]


def test_flash_attention_matches_reference():
    key = jax.random.PRNGKey(0)
    B, S, H, HK, DH = 2, 128, 8, 4, 16
    q = jax.random.normal(key, (B, S, H, DH))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, HK, DH))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, HK, DH))
    pos = jnp.arange(S)
    ref = attention._sdpa_block(q, k, v, pos, pos, True)
    for qc, kc in ((16, 32), (64, 64), (128, 16)):
        got = attention._sdpa_flash(q, k, v, pos, pos, True, q_chunk=qc,
                                    kv_chunk=kc)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2,
        )
