"""TWM vs BWM: functional equivalence + the Fig. 3(c) margin claim."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import twm


def test_twm_mac_equals_int_matmul():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.integers(0, 2, (11, 96)), jnp.uint32)
    w = jnp.array(rng.integers(-1, 2, (96, 17)), jnp.int32)
    got = twm.twm_mac(x, w)
    want = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_sensing_margins():
    assert twm.sensing_margin_twm() == 2.0 * twm.sensing_margin_bwm()


def test_ideal_sa_is_exact():
    sa = twm.SAModel(noise_sigma=0.0)
    d = jnp.array([-1.0, 0.0, 1.0])
    np.testing.assert_array_equal(np.asarray(sa.decide(d)), [0, 1, 1])


def test_twm_flips_less_than_bwm_under_noise():
    """The paper's margin argument: at equal SA noise, TWM decisions flip
    less often than BWM decisions (Fig. 3c)."""
    rng = np.random.default_rng(1)
    x = jnp.array(rng.integers(0, 2, (64, 128)), jnp.uint32)
    w = jnp.array(rng.integers(-1, 2, (128, 32)), jnp.int32)
    key = jax.random.PRNGKey(0)
    for sigma in (1.0, 2.0):
        ft = float(twm.flip_rate_under_noise(key, x, w, sigma, "twm", trials=16))
        fb = float(twm.flip_rate_under_noise(key, x, w, sigma, "bwm", trials=16))
        assert ft < fb, (sigma, ft, fb)
